"""Regenerate EXPERIMENTS.md: dry-run roofline tables + oracle sweep tables.

Two sections are (re)generated in place, each delimited by its own heading:
  * "### Baseline cells" / "### Hillclimb" — from launch/dryrun JSON
    artifacts in experiments/dryrun/ (empty tables when none exist yet),
  * "### Oracle sweep" — projected straight from the vectorized sweep
    engine (core/sweep.py): best strategy per scale for the paper's models,
    with bottleneck classification and the data→df crossover point.

Usage: PYTHONPATH=src python experiments/make_report.py
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

HDR = ("| arch | shape | mesh | strategy | comp ms | mem ms | coll ms | dom |"
       " useful | frac | args GiB | temp GiB |\n"
       "|---|---|---|---|---|---|---|---|---|---|---|---|")

SWEEP_HDR = ("| model | p | strategy | p1×p2 | total ms/iter | mem GiB |"
             " bottleneck |\n|---|---|---|---|---|---|---|")

SKELETON = """# EXPERIMENTS

Auto-generated tables — run `PYTHONPATH=src python experiments/make_report.py`.

### Baseline cells (required matrix)

### Hillclimb / variant cells (tagged)

### Oracle sweep (vectorized strategy × scale projections)

### Per-cell observations

(hand-written notes go here; everything above the marker is regenerated)
"""


def row(r):
    rl = r["roofline"]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['strategy']}"
            f"{('/' + r['tag']) if r.get('tag') else ''} | "
            f"{rl['compute_s']*1e3:,.1f} | {rl['memory_s']*1e3:,.1f} | "
            f"{rl['collective_s']*1e3:,.1f} | {rl['dominant'][:4]} | "
            f"{rl['useful_ratio']:.2f} | {rl['roofline_fraction']:.3f} | "
            f"{r['memory']['args_gib']:.1f} | {r['memory']['temp_gib']:.1f} |")


def dryrun_sections(here: pathlib.Path) -> tuple[str, int, int]:
    recs = [json.loads(f.read_text())
            for f in sorted((here / "dryrun").glob("*.json"))]
    base = [r for r in recs if not r.get("tag")]
    opt = [r for r in recs if r.get("tag")]
    out = ["### Baseline cells (required matrix)", "", HDR]
    out += [row(r) for r in base] or ["| _no dry-run artifacts yet_ |" + " |" * 11]
    out += ["", "### Hillclimb / variant cells (tagged)", "", HDR]
    out += [row(r) for r in opt] or ["| _no dry-run artifacts yet_ |" + " |" * 11]
    return "\n".join(out), len(base), len(opt)


def sweep_section() -> str:
    from repro.core import OracleConfig, PAPER_V100_CLUSTER, TimeModel, stats_for
    from repro.core.sweep import sweep
    from repro.models.cnn import CosmoFlowConfig, RESNET50, VGGConfig

    tm = TimeModel(PAPER_V100_CLUSTER)
    grid = [2 ** k for k in range(11)]
    out = ["### Oracle sweep (vectorized strategy × scale projections)", "",
           "Best deployable split per (model, p) on the paper's V100 "
           "cluster model, weak scaling 2 samples/PE; from "
           "`python -m repro.core.sweep`.", "", SWEEP_HDR]
    models = {"resnet50": (RESNET50, 1_281_167),
              "vgg16": (VGGConfig(), 1_281_167),
              "cosmoflow": (CosmoFlowConfig(img=128), 1584)}
    for name, (mc, D) in models.items():
        stats = stats_for(mc)
        batch_of = lambda p: max(2 * p, 4)            # noqa: E731
        cfg = OracleConfig(B=batch_of(grid[-1]), D=max(D, batch_of(grid[-1])))
        res = sweep(stats, tm, cfg, grid, batch_for_p=batch_of,
                    mem_cap=tm.system.mem_capacity)
        best = res.best_per_p()
        for p in grid:
            sub = best.select(best.p == p)
            if not len(sub):
                continue
            i = int(sub.total_s.argmin())
            it = max(float(sub.iterations[i]), 1.0)
            out.append(f"| {name} | {p} | {sub.strategy[i]} | "
                       f"{int(sub.p1[i])}×{int(sub.p2[i])} | "
                       f"{float(sub.total_s[i])/it*1e3:,.2f} | "
                       f"{float(sub.mem_bytes[i])/2**30:.2f} | "
                       f"{sub.bottleneck[i]} |")
        x = res.crossover("data", "df")
        out.append(f"\ndata→df crossover for {name}: "
                   f"{'p=%d' % x if x else 'not on this grid'}\n")
    return "\n".join(out)


def replace_between(text: str, start_marker: str, end_marker: str,
                    new: str) -> str:
    start = text.index(start_marker)
    end = text.index(end_marker)
    return text[:start] + new + "\n\n" + text[end:]


def main():
    here = pathlib.Path(__file__).parent
    exp = here.parent / "EXPERIMENTS.md"
    if not exp.exists():
        exp.write_text(SKELETON)
    t = exp.read_text()
    dry, n_base, n_opt = dryrun_sections(here)
    t = replace_between(t, "### Baseline cells",
                        "### Oracle sweep", dry)
    t = replace_between(t, "### Oracle sweep",
                        "### Per-cell observations", sweep_section())
    exp.write_text(t)
    print(f"refreshed: {n_base} baseline + {n_opt} variant dry-run cells "
          f"+ oracle sweep tables")


if __name__ == "__main__":
    main()
