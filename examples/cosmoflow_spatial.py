"""The paper's flagship hybrid: CosmoFlow with Data+Spatial (ds) parallelism.

3-D volumes are too large for pure data parallelism (paper §5.1: 0.25
samples/GPU); ds splits the volume's spatial dims inside a group and runs
data parallelism across groups. This example trains a reduced CosmoFlow
under ds on the host mesh and prints the oracle's projection next to the
measured step time (paper Fig. 4/5 in miniature).

Run:  PYTHONPATH=src python examples/cosmoflow_spatial.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.core import OracleConfig, TimeModel, project, stats_for
from repro.core.calibration import calibrate_host_system
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.launch.mesh import make_host_mesh
from repro.models.cnn import CosmoFlow, CosmoFlowConfig
from repro.nn.module import ShardingCtx, tree_init
from repro.optim.optimizers import OptimizerConfig
from repro.parallel.strategies import make_rules
from repro.training.steps import make_train_step, train_state_spec


def main():
    mc = CosmoFlowConfig(img=32, n_conv=3, width=8)
    model = CosmoFlow(mc)
    mesh = make_host_mesh()
    ctx = ShardingCtx(mesh, make_rules("ds"))
    opt = OptimizerConfig(name="sgd", lr=1e-3, zero1=False)
    step = jax.jit(make_train_step(model, opt, ctx))
    state = tree_init(train_state_spec(model, opt), jax.random.PRNGKey(0))
    loader = ShardedLoader(DataConfig("volume", batch=8, image=32, channels=4,
                                      n_targets=4), mesh)
    # measure a few steps
    for t in range(3):
        state, metrics = step(state, loader.batch_at(t))
    jax.block_until_ready(metrics["loss"])
    t0 = time.time()
    for t in range(3, 8):
        state, metrics = step(state, loader.batch_at(t))
        jax.block_until_ready(metrics["loss"])
    meas = (time.time() - t0) / 5
    print(f"measured ds step: {meas*1e3:.1f} ms  "
          f"(loss {float(metrics['mse']):.4f})")

    # oracle projection of the same point
    stats = stats_for(mc)
    flops = sum(s.flops_fwd for s in stats) * 8
    sysm = calibrate_host_system(lambda p, b: model.loss_fn(p, b),
                                 tree_init(model.params_spec(),
                                           jax.random.PRNGKey(0)),
                                 loader.batch_at(0), flops, mesh=mesh)
    import dataclasses
    import numpy as np
    p = int(np.prod(list(mesh.shape.values())))
    sysm = dataclasses.replace(sysm, peak_flops=sysm.peak_flops / p)
    proj = project("ds", stats, TimeModel(sysm), OracleConfig(B=8, D=8), p,
                   p1=mesh.shape.get("data", 1), p2=mesh.shape.get("model", 1))
    acc = 1 - abs(proj.total_s - meas) / meas
    print(f"oracle projection: {proj.total_s*1e3:.1f} ms  "
          f"→ accuracy {acc*100:.1f}% (paper metric)")


if __name__ == "__main__":
    main()
