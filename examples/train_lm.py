"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

A qwen3-family model (d_model 512, 8 layers, 32k vocab ≈ 103M params) on the
deterministic synthetic pipeline, with ZeRO-1 AdamW, remat, checkpointing and
the fault-tolerant loop — the full production path at laptop scale.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointing import Checkpointer
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.models import LMConfig, TransformerLM
from repro.nn import AttentionConfig, FFNConfig
from repro.nn.module import ShardingCtx, tree_init
from repro.optim.optimizers import OptimizerConfig
from repro.parallel.strategies import make_rules
from repro.runtime.fault_tolerance import run_with_recovery
from repro.training.steps import make_train_step, train_state_spec
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm_100m")
    args = ap.parse_args()

    cfg = LMConfig(
        name="lm-100m", vocab=32768, d_model=512, n_layers=8,
        attn=AttentionConfig(512, 8, 4, 64, qk_norm=True, dtype=jnp.float32),
        ffn=FFNConfig(512, 2048, dtype=jnp.float32), dtype=jnp.float32)
    model = TransformerLM(cfg)
    print(f"model: {model.num_params()/1e6:.1f}M params")

    mesh = make_host_mesh()
    ctx = ShardingCtx(mesh, make_rules("df"))
    opt = OptimizerConfig(lr=3e-3, zero1=True)
    step = jax.jit(make_train_step(model, opt, ctx, scan_layers=True,
                                   attn_impl="chunked", q_chunk=128),
                   donate_argnums=(0,))
    state = tree_init(train_state_spec(model, opt), jax.random.PRNGKey(0))
    loader = ShardedLoader(DataConfig("lm", batch=args.batch,
                                      seq_len=args.seq, vocab=cfg.vocab), mesh)
    ckpt = Checkpointer(args.ckpt_dir, config_tag="lm-100m")

    t0 = time.time()
    losses = []

    def on_metrics(s, m):
        losses.append(float(m["loss"]))
        if s % 10 == 0:
            tps = args.batch * args.seq / max((time.time() - t0) / (s + 1), 1e-9)
            print(f"step {s:4d}  loss {losses[-1]:.4f}  "
                  f"~{tps:,.0f} tok/s", flush=True)

    start = ckpt.latest_step() or 0
    if start:
        state, start = ckpt.restore(state)
        print(f"resumed from step {start}")
    state, final = run_with_recovery(step, state, loader, ckpt,
                                     n_steps=args.steps, start_step=start,
                                     ckpt_every=100, on_metrics=on_metrics)
    print(f"finished at step {final}: loss {losses[0]:.3f} → {losses[-1]:.3f} "
          f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
