"""Batched serving example: prefill + greedy decode with KV caches.

Uses the reduced qwen3 config and both KV-cache layouts (classic per-head vs
sequence-sharded flash-decoding) to show the serving path end-to-end.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main


def main():
    print("--- classic per-head KV cache ---")
    serve_main(["--arch", "qwen3-32b", "--smoke", "--batch", "4",
                "--prompt-len", "32", "--gen", "16"])
    print("--- sequence-sharded (flash-decoding) KV cache ---")
    serve_main(["--arch", "qwen3-32b", "--smoke", "--batch", "4",
                "--prompt-len", "32", "--gen", "16", "--kv-shards", "2",
                "--strategy", "serve_seqkv"])


if __name__ == "__main__":
    main()
