"""Serving example: continuous batching through the paged KV cache.

Replays a small synthetic trace through the engine (`repro.serve.Engine`
via the `launch/serve.py` CLI) under both serving rules tables — classic
per-head KV sharding (`serve_tp`) and the sequence-sharded
flash-decoding layout (`serve_seqkv`) — on the reduced qwen3 config.
Both runs emit identical tokens: the cache layout is invisible to the
math (tests/test_serve.py pins this against a dense solo decode).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main


def main():
    print("--- serve_tp: classic per-head KV cache ---")
    serve_main(["--arch", "qwen3-32b", "--smoke", "--max-batch", "4",
                "--requests", "6", "--rate", "50", "--prompt-len", "32",
                "--gen", "16", "--closed-loop"])
    print("--- serve_seqkv: sequence-sharded (flash-decoding) KV cache ---")
    serve_main(["--arch", "qwen3-32b", "--smoke", "--max-batch", "4",
                "--requests", "6", "--rate", "50", "--prompt-len", "32",
                "--gen", "16", "--closed-loop", "--kv-shards", "2",
                "--strategy", "serve_seqkv"])


if __name__ == "__main__":
    main()
