"""Quickstart: the ParaDL oracle on the paper's headline question.

"Which parallel strategy should train ResNet-50 / VGG16 on a 1024-GPU
cluster?" (paper §5) — and the same question for qwen3-32b on a TPU v5e pod.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import (OracleConfig, PAPER_V100_CLUSTER, TPU_V5E_POD,
                        TimeModel, advise, breakdown_table, stats_for)
from repro.models.cnn import RESNET50, VGGConfig


def headline(title, stats, tm, cfg, p, mem_cap):
    rec = advise(stats, tm, cfg, p, mem_cap=mem_cap)
    print(f"\n=== {title} (p={p}) ===")
    print(breakdown_table(rec.ranked))
    if rec.best:
        it = rec.best.per_iteration()
        print(f"--> best: {rec.best.strategy} (p1={rec.best.p1}, "
              f"p2={rec.best.p2}); {it['total_s']*1e3:.1f} ms/iter")
    for proj, why in rec.rejected[:4]:
        print(f"    rejected {proj.strategy:8s} p1={proj.p1:<4d} "
              f"p2={proj.p2:<4d} — {why}")


def main():
    tm_gpu = TimeModel(PAPER_V100_CLUSTER)
    # paper scales: weak scaling, V100 memory cap 16 GB
    for p in (64, 256, 1024):
        headline("ResNet-50 / ImageNet / V100 cluster",
                 stats_for(RESNET50), tm_gpu,
                 OracleConfig(B=2 * p, D=1_281_167), p, 16e9)
    headline("VGG16 / ImageNet / V100 cluster", stats_for(VGGConfig()),
             tm_gpu, OracleConfig(B=1024, D=1_281_167), 1024, 16e9)

    # beyond paper: the same oracle on a TPU v5e pod for an assigned arch
    lm = get_config("qwen3-32b").model
    headline("qwen3-32b / 4k seq / TPU v5e pod",
             stats_for(lm, 4096), TimeModel(TPU_V5E_POD),
             OracleConfig(B=256, D=256 * 100, zero1=True, remat=True,
                          zero3=True, seq_parallel=True), 256, 16e9)


if __name__ == "__main__":
    main()
