"""Quickstart: the ParaDL oracle on the paper's headline question.

"Which parallel strategy should train ResNet-50 / VGG16 on a 1024-GPU
cluster?" (paper §5) — and the same question for qwen3-32b on a TPU v5e
pod — through the ``Oracle`` session facade (DESIGN.md §11): bind
(arch × shape × ClusterSpec) once, then ask.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.api import Oracle, Torus
from repro.core import breakdown_table


def headline(title, ses, p):
    rec = ses.advise(p)
    print(f"\n=== {title} (p={p}) ===")
    print(breakdown_table(rec.ranked))
    if rec.best:
        it = rec.best.per_iteration()
        print(f"--> best: {rec.best.strategy} (p1={rec.best.p1}, "
              f"p2={rec.best.p2}); {it['total_s']*1e3:.1f} ms/iter")
    for proj, why in rec.rejected[:4]:
        print(f"    rejected {proj.strategy:8s} p1={proj.p1:<4d} "
              f"p2={proj.p2:<4d} — {why}")


def main():
    # paper scales: weak scaling, V100 memory cap 16 GB
    for p in (64, 256, 1024):
        headline("ResNet-50 / ImageNet / V100 cluster",
                 Oracle("resnet50", "train_4k", "paper", batch=2 * p,
                        dataset=1_281_167, mem_cap=16e9), p)
    headline("VGG16 / ImageNet / V100 cluster",
             Oracle("vgg16", "train_4k", "paper", batch=1024,
                    dataset=1_281_167, mem_cap=16e9), 1024)

    # beyond paper: the same oracle on a TPU v5e pod for an assigned arch
    headline("qwen3-32b / 4k seq / TPU v5e pod",
             Oracle("qwen3-32b", "train_4k", "tpu", batch=256,
                    dataset=256 * 100, mem_cap=16e9, zero1=True, remat=True,
                    zero3=True, seq_parallel=True), 256)

    # the machine is one argument: constrain the model axis to one torus
    # dim and the tuner reroutes around the pruned factorizations
    import dataclasses
    ses = Oracle("cosmoflow", "train_4k", "paper", batch=2, dataset=1584)
    free = ses.tune(8)
    bound = ses.with_cluster(dataclasses.replace(
        ses.cluster, topology=Torus((4, 2)))).tune(8)
    print(f"\n=== CosmoFlow p=8: topology changes the plan ===")
    print(f"unconstrained: {free.strategy} {free.p1}x{free.p2}")
    print(f"(4,2)-torus:   {bound.strategy} {bound.p1}x{bound.p2} "
          f"(no 8-wide model ring exists)")


if __name__ == "__main__":
    main()
